#!/usr/bin/env bash
# Tier-1 gate + kernel perf snapshot with a regression gate. Run from
# anywhere:
#
#     tools/ci.sh
#
# The kernel + fora-hot-path benches run TWICE and the per-row minima (each
# row is already a min-of-repeats, benchmarks/common.py) are compared against
# the COMMITTED BENCH_kernels.json baseline (git HEAD when available, else
# the working-tree file) through tools/bench_compare.py with a band ($BENCH_TOL,
# default 2.0x), FAILING the build on regression. Comparing against the
# committed file — not the last run's output — keeps repeated sub-tolerance
# slowdowns from ratcheting past the band unnoticed. On a passing run the
# working-tree baseline is refreshed with the min-merge; committing it
# records the per-PR perf trajectory.
set -euo pipefail
cd "$(dirname "$0")/.."

# every leg below runs through tools/run.sh so allocator / XLA topology /
# log-level hygiene is identical across legs (DESIGN.md §15)
RUN=tools/run.sh

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# dnalint (DESIGN.md §13): the repo's invariant analyzer is a HARD gate —
# src/ must be clean modulo the committed (empty) baseline, and the seeded
# bad fixtures must still be caught (a lint that stops firing is a lint
# that silently rotted)
$RUN python -m tools.analysis --baseline tools/analysis/baseline.json
if $RUN python -m tools.analysis tests/analysis_fixtures/bad > /dev/null 2>&1
then
    echo "dnalint failed to flag the seeded bad fixtures" >&2
    exit 1
fi

# ruff (pinned in requirements-dev.txt, config in ruff.toml) — skipped when
# the container image doesn't ship it; dnalint above is the hard gate
if command -v ruff > /dev/null 2>&1
then
    ruff check .
else
    echo "ruff not installed — skipping (see requirements-dev.txt)"
fi

# the forced-8-device leg below covers the sharded subprocess test directly,
# so the main run skips the redundant inner relaunch
REPRO_SHARDED_SUBPROCESS=skip $RUN python -m pytest -x -q

# multi-device PPR: sharded-vs-single parity, transfer guard, executor
# devices=k — on a host platform forced to 8 devices (DESIGN.md §9)
REPRO_HOST_DEVICES=8 $RUN python -m pytest -x -q tests/test_sharded.py \
    -k "not subprocess"

# autotune smoke (DESIGN.md §15): tiny sweep populates a throwaway tuning
# cache, then a second invocation must HIT it (exercises the atomic JSON
# round-trip + shape-bucket key stability end to end)
at_dir=$(mktemp -d)
trap 'rm -rf "$at_dir"' EXIT
$RUN python -m repro.kernels.autotune --smoke --cache "$at_dir/tune.json"
$RUN python -m repro.kernels.autotune --smoke --cache "$at_dir/tune.json" \
    --expect-hit
rm -rf "$at_dir"

# serving-runtime smoke (DESIGN.md §10): deterministic seeded replay,
# >= 95% deadline hit-rate, core-hours strictly below static Lemma-2, and
# the failure-injection run completing via readmission (no job loss)
$RUN python -m benchmarks.serving_sim --check

# continuous-batching engine smoke (DESIGN.md §14): same burst trace
# through the chunked and engine paths — engine must be deterministic,
# keep the 100% SLA hit-rate, and deliver >= 1.5x queries/sec
$RUN python -m benchmarks.serving_sim --check --engine

# warm-cache smoke (DESIGN.md §11): cold leg bit-for-bit equal to the
# uncached serving path, warm leg >= 30% core-hours reduction at 100% SLA
$RUN python -m benchmarks.index_cache --check

# chaos smoke (DESIGN.md §12): WAL-attached run with device failure, lane
# slowdowns and two process crashes — recovery must be crash-transparent
# (records bit-identical to the uncrashed run) with zero job loss
$RUN python -m benchmarks.serving_sim --chaos

# engine-mode chaos smoke (DESIGN.md §14): the same fault schedule through
# the continuous-batching path — crash-transparent, zero job loss, with
# lane-occupancy accounting surviving recovery
$RUN python -m benchmarks.serving_sim --chaos --engine

# churn smoke (DESIGN.md §16): the anchor workload under a seeded graph-
# mutation stream — deterministic replay, anchor SLA hit-rate fully
# sustained, incremental refresh < 25% of full-rebuild core-seconds
$RUN python -m benchmarks.serving_sim --check --mutation-rate 0.5

trap 'rm -f BENCH_kernels.committed.json BENCH_kernels.fresh1.json \
            BENCH_kernels.fresh2.json BENCH_kernels.merged.json' EXIT
$RUN python -m benchmarks.run --only kernels,fora_hot,serving,index --json BENCH_kernels.fresh1.json
$RUN python -m benchmarks.run --only kernels,fora_hot,serving,index --json BENCH_kernels.fresh2.json

baseline=BENCH_kernels.json
if git show HEAD:BENCH_kernels.json > BENCH_kernels.committed.json 2>/dev/null
then
    baseline=BENCH_kernels.committed.json
fi
$RUN python tools/bench_compare.py "$baseline" \
    BENCH_kernels.fresh1.json BENCH_kernels.fresh2.json \
    --tol "${BENCH_TOL:-2.0}" --merged-out BENCH_kernels.merged.json
mv BENCH_kernels.merged.json BENCH_kernels.json
