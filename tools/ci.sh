#!/usr/bin/env bash
# Tier-1 gate + kernel perf snapshot. Run from anywhere:
#
#     tools/ci.sh
#
# Writes BENCH_kernels.json at the repo root (the per-PR perf trajectory).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q
python -m benchmarks.run --only kernels --json BENCH_kernels.json
