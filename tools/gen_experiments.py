"""Generate EXPERIMENTS.md sections from reports/ JSON. Run after sweeps:

    PYTHONPATH=src python tools/gen_experiments.py > EXPERIMENTS_tables.md
"""

from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DRY = ROOT / "reports" / "dryrun"
HC = ROOT / "reports" / "hillclimb"


def fmt(x, nd=4):
    return f"{x:.{nd}g}"


def dryrun_table(mesh: str) -> str:
    rows = ["| arch | shape | kind | status | compile_s | temp bytes/dev | coll counts |",
            "|---|---|---|---|---|---|---|"]
    for p in sorted(DRY.glob(f"*__{mesh}.json")):
        r = json.loads(p.read_text())
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['kind']} | "
                        f"SKIP (sub-quadratic gate) | — | — | — |")
            continue
        mem = r.get("memory_analysis", {})
        temp = mem.get("temp_size_in_bytes", 0)
        cc = r.get("collectives", {}).get("count_by_kind", {})
        cc_s = " ".join(f"{k.split('-')[-1]}:{v}" for k, v in sorted(cc.items()))
        rows.append(f"| {r['arch']} | {r['shape']} | {r['kind']} | ok | "
                    f"{r.get('compile_s', 0):.1f} | {temp / 2**30:.2f} GiB | {cc_s} |")
    return "\n".join(rows)


def roofline_table() -> str:
    rows = ["| arch | shape | compute_s | memory_s (HLO) | collective_s | "
            "memory_s (model) | dominant | dom (fused) | useful ratio | "
            "MFU | MFU (fused) |", "|---|---|---|---|---|---|---|---|---|---|---|"]
    for p in sorted(DRY.glob("*__pod16x16.json")):
        r = json.loads(p.read_text())
        if r["status"] != "ok":
            continue
        rf = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt(rf['compute_s'])} | "
            f"{fmt(rf['memory_s'])} | {fmt(rf['collective_s'])} | "
            f"{fmt(rf['memory_model_s'])} | {rf['dominant']} | "
            f"{rf['dominant_fused']} | {rf['useful_flops_ratio']:.2f} | "
            f"{rf['mfu']:.3f} | {rf['mfu_fused']:.3f} |")
    return "\n".join(rows)


def hillclimb_tables() -> str:
    out = []
    for log in sorted(HC.glob("LOG_*.json")):
        cell = log.stem.split("_", 1)[1]
        rows = [f"### {cell}", "",
                "| variant | compute_s | memory_s | collective_s | "
                "step_s | step_fused_s | MFU | MFU fused |",
                "|---|---|---|---|---|---|---|---|"]
        for v in json.loads(log.read_text()):
            if v["status"] != "ok":
                rows.append(f"| {v['variant']} | ERROR | | | | | | |")
                continue
            rf = v["roofline"]
            rows.append(f"| {v['variant']} | {fmt(rf['compute_s'])} | "
                        f"{fmt(rf['memory_s'])} | {fmt(rf['collective_s'])} | "
                        f"{fmt(rf['step_s'])} | {fmt(rf['step_fused_s'])} | "
                        f"{rf['mfu']:.3f} | {rf['mfu_fused']:.3f} |")
        out.append("\n".join(rows))
    # extra variants saved outside LOG files
    extra = [p for p in sorted(HC.glob("*.json")) if not p.name.startswith("LOG")]
    if extra:
        rows = ["### all recorded variant runs", "",
                "| cell | variant | step_s | step_fused_s | dominant(fused) | MFU fused |",
                "|---|---|---|---|---|---|"]
        for p in extra:
            r = json.loads(p.read_text())
            if r["status"] != "ok":
                continue
            rf = r["roofline"]
            rows.append(f"| {r['arch']}/{r['shape']} | {r.get('variant')} | "
                        f"{fmt(rf['step_s'])} | {fmt(rf['step_fused_s'])} | "
                        f"{rf['dominant_fused']} | {rf['mfu_fused']:.3f} |")
        out.append("\n".join(rows))
    return "\n\n".join(out)


if __name__ == "__main__":
    print("## Dry-run single-pod (16x16)\n")
    print(dryrun_table("pod16x16"))
    print("\n## Dry-run multi-pod (2x16x16)\n")
    print(dryrun_table("pod2x16x16"))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table())
    print("\n## Hillclimb\n")
    print(hillclimb_tables())
