#!/usr/bin/env python3
"""Benchmark regression gate: compare fresh run(s) against the committed
baseline JSON (all produced by ``benchmarks/run.py --json``).

    python tools/bench_compare.py BASELINE FRESH [FRESH2 ...]
                                  [--tol 2.0] [--merged-out PATH]

Rules:
* every fresh run must have recorded zero suite failures;
* multiple fresh files are min-merged per row first — the per-call floor
  across independent process runs is the noise-robust statistic on a loaded
  box (each row is already a min-of-repeats within its run, see
  ``benchmarks.common.timed``);
* every row present in BOTH baseline and merge must satisfy
  ``new <= tol * old`` (``old`` also gates deterministic values like
  resident MiB, where any growth past the band is a layout regression);
* rows only on one side are informational (new benchmarks land with their
  first baseline; retired ones drop out);
* aggregate ``suite/*`` rows are informational only (they fold compile time
  and machine load into one number — the per-kernel rows are the gate);
* a missing baseline file passes with a note (first run of a trajectory);
* ``--merged-out`` writes the min-merged measurement set as the next
  baseline candidate.

Exit code 0 = gate passed, 1 = regression (or fresh failures).
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def rows_by_name(payload: dict) -> dict[str, dict]:
    return {r["name"]: r for r in payload.get("rows", [])}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("fresh", nargs="+")
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get("BENCH_TOL", "2.0")),
                    help="fail when new > tol * old (default 2.0, or "
                         "$BENCH_TOL)")
    ap.add_argument("--merged-out", default="",
                    help="write the min-merged fresh rows to this path")
    args = ap.parse_args()
    if args.tol <= 1.0:
        ap.error("--tol must be > 1.0")

    merged: dict[str, dict] = {}
    failures = 0
    for path in args.fresh:
        payload = load(path)
        failures += int(payload.get("failures", 0))
        for name, row in rows_by_name(payload).items():
            if name not in merged or \
                    row["us_per_call"] < merged[name]["us_per_call"]:
                merged[name] = row
    if args.merged_out:
        with open(args.merged_out, "w") as f:
            json.dump({"rows": list(merged.values()), "failures": failures},
                      f, indent=2)
            f.write("\n")
    if failures:
        print(f"bench_compare: FRESH RUN(S) RECORDED {failures} SUITE "
              "FAILURE(S) — gate fails")
        return 1

    if not os.path.exists(args.baseline):
        print(f"bench_compare: no baseline at {args.baseline} — "
              "first run, gate passes")
        return 0
    base = {n: float(r["us_per_call"])
            for n, r in rows_by_name(load(args.baseline)).items()}

    regressions: list[str] = []
    for name in sorted(set(base) | set(merged)):
        if name not in merged:
            print(f"  {name}: retired (baseline only)")
            continue
        new = float(merged[name]["us_per_call"])
        if name not in base:
            print(f"  {name}: new (no baseline yet) = {new:.1f}")
            continue
        old = base[name]
        if old <= 0:
            print(f"  {name}: baseline <= 0, skipped")
            continue
        ratio = new / old
        gated = not name.startswith("suite/")
        bad = gated and ratio > args.tol
        tag = "REGRESSION" if bad else ("info" if not gated else "ok")
        print(f"  {name}: {old:.1f} -> {new:.1f} ({ratio:.2f}x) {tag}")
        if bad:
            regressions.append(f"{name} {ratio:.2f}x > {args.tol:.2f}x")
    if regressions:
        print("bench_compare: FAILED —")
        for r in regressions:
            print(f"  {r}")
        return 1
    print(f"bench_compare: gate passed (tol {args.tol:.2f}x)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
