"""§Roofline aggregation: per-cell three-term table from the dry-run reports.

Reads reports/dryrun/*.json (written by repro.launch.sweep / dryrun) and
emits one CSV row per (arch, shape, mesh) with compute/memory/collective
seconds, the dominant term under both memory views, MODEL_FLOPS ratio and
roofline MFU. This is the generator for EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import json
from pathlib import Path

from .common import emit

REPORT_DIR = Path(__file__).resolve().parents[1] / "reports" / "dryrun"


def run(mesh: str | None = None) -> None:
    if not REPORT_DIR.exists():
        emit("roofline/missing", 0.0, "run repro.launch.sweep first")
        return
    rows = 0
    for path in sorted(REPORT_DIR.glob("*.json")):
        r = json.loads(path.read_text())
        if mesh and r.get("mesh") != mesh:
            continue
        tag = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r["status"] == "skipped":
            emit(tag, 0.0, "skipped=" + r["reason"][:60].replace(",", ";"))
            continue
        if r["status"] != "ok":
            emit(tag, 0.0, "error=" + r["error"][:60].replace(",", ";"))
            continue
        rf = r["roofline"]
        emit(tag, rf["step_s"] * 1e6,
             f"compute_s={rf['compute_s']:.4g};memory_s={rf['memory_s']:.4g};"
             f"collective_s={rf['collective_s']:.4g};"
             f"memory_model_s={rf['memory_model_s']:.4g};"
             f"dominant={rf['dominant']};dominant_fused={rf['dominant_fused']};"
             f"useful_ratio={rf['useful_flops_ratio']:.3f};"
             f"mfu={rf['mfu']:.3f};mfu_fused={rf['mfu_fused']:.3f}")
        rows += 1
    emit("roofline/total_rows", 0.0, f"rows={rows}")
