"""Paper Fig. 3: scaling-factor comparison on Web-Stanford.

d = 1.00 vs d = 0.85 with all other variables fixed: a lower d must yield
fewer slots -> MORE cores -> SHORTER completion (paper §IV-B observation).
Both directions are asserted (ties allowed at coarse grids).
"""

from __future__ import annotations

from repro.core import InfeasibleDeadline, dna_real, fraction_sample_size
from repro.ppr import ForaExecutor, ForaParams, PprWorkload
from repro.ppr.datasets import TABLE1, synthesize

from .common import emit


def run(scale: int = 512, X: int = 96, seed: int = 0) -> None:
    spec = TABLE1["web-stanford"]
    graph = synthesize(spec, scale=scale, seed=seed)
    # ONE deadline for both d values — the paper's "all other variables
    # remain" condition; computed once from a steady-state probe.
    workload0 = PprWorkload(graph=graph, num_queries=X, seed=seed)
    executor0 = ForaExecutor(workload=workload0, params=ForaParams())
    s = fraction_sample_size(X, 0.05)
    executor0(list(range(s)))
    probe = executor0(list(range(s)))
    deadline = max(X * probe.t_avg / 4, probe.t_max * 6, probe.t_pre * 8)
    results = {}
    for d in (1.00, 0.85):
        workload = PprWorkload(graph=graph, num_queries=X, seed=seed)
        executor = ForaExecutor(workload=workload, params=ForaParams())
        executor(list(range(s)))          # steady state
        res, T = None, deadline
        for _ in range(3):                # §III-A extension on jitter
            try:
                res = dna_real(X, T, executor, max_cores=64, sample_size=s,
                               scaling_factor=d)
                break
            except InfeasibleDeadline:
                T *= 1.5
        assert res is not None, "rejected after extensions"
        deadline = T                      # keep T common for the second d
        results[d] = res
        emit(f"fig3/web-stanford/d{d:.2f}", res.sample_stats.t_avg * 1e6,
             f"cores={res.cores};completion={res.completion_time:.2f}s;"
             f"ell={res.ell};T={deadline:.2f}s")
    lo, hi = results[0.85], results[1.00]
    # +1 jitter slack: single wall-clock measurements on a shared host
    assert lo.cores + 1 >= hi.cores, \
        f"smaller d must not reduce cores ({lo.cores} << {hi.cores})"
    emit("fig3/web-stanford/assert", 0.0,
         f"d0.85_cores={lo.cores}>=d1.00_cores={hi.cores};"
         f"d0.85_completion={lo.completion_time:.2f}s;"
         f"d1.00_completion={hi.completion_time:.2f}s")
