"""FORA serving hot path: seed baseline vs legacy vs the fused pipeline.

Three per-query measurements at the acceptance shape (``small_test_graph``,
1k-query workload):

* ``seed``   — pinned replica of the pre-PR ``fora()`` hot path: graph
  arrays re-staged on every query, COO ``segment_sum`` push
  (``forward_push_coo`` *is* the seed push), per-step split/uniform/randint
  walk RNG, and two host round-trips between push and walk. This is the
  baseline the >=2x acceptance criterion is measured against.
* ``legacy`` — today's multi-call ``fora()``: shares the PR's ELL push and
  bulk-RNG walks but keeps the host syncs between phases.
* ``fused``  — ``fora_fused`` via :class:`ForaExecutor`: one jitted call per
  query on a :class:`DeviceGraph`, host touched only at readout
  (DESIGN.md §7).

The seed replica lives here (not in src/) so the serving code carries no
dead baseline; it reproduces the seed maths verbatim and is clocked with the
same warmup discipline as the executors.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.ppr import (ForaExecutor, ForaParams, PprWorkload, fora_fused,
                       small_test_graph)
from repro.ppr.forward_push import forward_push_coo
from repro.ppr.graph import Graph
from repro.ppr.random_walk import walk_length_for_tail

from .common import emit

NUM_QUERIES = 1000
BASELINE_QUERIES = 250  # slow paths: their mean stabilises well before 1k


@partial(jax.jit, static_argnames=("n", "num_walks", "num_steps"))
def _seed_residual_walks(edge_dst, out_offsets, out_degree, residual, key, *,
                         alpha, n, num_walks, num_steps):
    """Verbatim seed walk loop: 3 RNG ops per step inside the scan."""
    r_sum = residual.sum()
    csum = jnp.cumsum(residual)
    k_start, k_walk = jax.random.split(key)
    u = jax.random.uniform(k_start, (num_walks,)) * r_sum
    starts = jnp.clip(jnp.searchsorted(csum, u, side="left").astype(jnp.int32),
                      0, n - 1)
    deg = jnp.maximum(out_degree, 1).astype(jnp.int32)

    def step(carry, step_key):
        pos, alive = carry
        k_stop, k_next = jax.random.split(step_key)
        stop = jax.random.uniform(k_stop, (num_walks,)) < alpha
        u_next = jax.random.randint(k_next, (num_walks,), 0, 1 << 30)
        nxt = edge_dst[out_offsets[pos] + (u_next % deg[pos])]
        new_alive = jnp.logical_and(alive, jnp.logical_not(stop))
        return (jnp.where(new_alive, nxt, pos), new_alive), None

    keys = jax.random.split(k_walk, num_steps)
    (endpos, _), _ = jax.lax.scan(step, (starts, jnp.ones(num_walks, bool)),
                                  keys)
    return jax.ops.segment_sum(
        jnp.full((num_walks,), r_sum / num_walks, residual.dtype), endpos,
        num_segments=n)


def _seed_fora(graph, sources: np.ndarray, params: ForaParams,
               key: jax.Array) -> np.ndarray:
    """Pinned seed ``fora()``: per-call device staging + host syncs."""
    rp = params.resolve(graph)
    sources = np.asarray(sources, dtype=np.int32).reshape(-1)
    seeds = np.zeros((sources.size, graph.n), dtype=np.float32)
    seeds[np.arange(sources.size), sources] = 1.0
    push = forward_push_coo(jnp.asarray(graph.edge_src),          # re-upload
                            jnp.asarray(graph.edge_dst),
                            jnp.asarray(graph.out_degree),
                            jnp.asarray(seeds), alpha=rp.alpha,
                            rmax=rp.rmax, n=graph.n)
    residual = np.asarray(push.r)                                 # sync 1
    r_sum = residual.sum(axis=1)
    walks = int(min(rp.max_walks,
                    max(1, math.ceil(float(r_sum.max()) * rp.omega))))
    walks = 1 << (walks - 1).bit_length()
    steps = walk_length_for_tail(rp.alpha, rp.walk_tail)
    keys = jax.random.split(key, residual.shape[0])
    endpoint = jax.vmap(lambda r, k: _seed_residual_walks(
        jnp.asarray(graph.edge_dst), jnp.asarray(graph.out_offsets),
        jnp.asarray(graph.out_degree), r, k, alpha=rp.alpha, n=graph.n,
        num_walks=walks, num_steps=steps))(jnp.asarray(residual), keys)
    return np.asarray(push.pi) + np.asarray(endpoint)             # sync 2


def _time_seed_path(workload: PprWorkload, params: ForaParams,
                    num_queries: int) -> float:
    import time
    for qid in (0, 1, num_queries // 2, num_queries - 1):         # warmup
        _seed_fora(workload.graph, np.array([workload.source_of(qid)]),
                   params, jax.random.PRNGKey(qid))
    times = np.empty(num_queries)
    for i in range(num_queries):
        src = np.array([workload.source_of(i)])
        t0 = time.perf_counter()
        _seed_fora(workload.graph, src, params, jax.random.PRNGKey(i))
        times[i] = time.perf_counter() - t0
    return float(np.mean(times))


def run(num_queries: int = NUM_QUERIES,
        baseline_queries: int = BASELINE_QUERIES) -> None:
    graph = small_test_graph(n=200, avg_deg=8, seed=1)
    params = ForaParams(alpha=0.2, epsilon=0.5)
    workload = PprWorkload(graph, num_queries=num_queries, seed=0)
    shape = f"n={graph.n};m={graph.m};queries={num_queries}"
    nb = min(baseline_queries, num_queries)

    seed_us = _time_seed_path(workload, params, nb) * 1e6
    emit("fora/seed_per_query", seed_us, f"{shape};measured={nb}")

    legacy = ForaExecutor(workload, params, fused=False)
    legacy_us = float(np.mean(legacy(list(range(nb))).times)) * 1e6
    emit("fora/legacy_per_query", legacy_us, f"{shape};measured={nb}")

    fused = ForaExecutor(workload, params, fused=True)
    fused_us = float(np.mean(fused(list(range(num_queries))).times)) * 1e6
    emit("fora/fused_per_query", fused_us,
         f"{shape};walk_budget={fused._num_walks}")

    emit("fora/hot_path_speedup", fused_us,
         f"vs_seed={seed_us / fused_us:.2f}x;"
         f"vs_legacy={legacy_us / fused_us:.2f}x;target_vs_seed>=2x")

    _run_sharded(workload, params, fused._num_walks, nb)
    _run_powerlaw()


def _run_sharded(workload: PprWorkload, params: ForaParams,
                 walk_budget: int, num_queries: int) -> None:
    """The same fused hot path through the node-sharded residency
    (DESIGN.md §9): `fora_fused` under shard_map over every local device.
    On the single-device CI box this prices the shard_map wrapper itself
    (all-gather/psum degenerate to copies), so the tolerance gate catches a
    wrapper regression; on a real mesh the row measures row/lane scaling."""
    import time

    graph = workload.graph
    k = len(jax.devices())
    mesh = jax.sharding.Mesh(np.array(jax.devices()), ("shard",))
    sdg = graph.device(mesh=mesh)
    for qid in (0, num_queries - 1):                         # warmup/compile
        res = fora_fused(sdg, np.array([workload.source_of(qid)]), params,
                         jax.random.PRNGKey(qid), num_walks=walk_budget)
        res.pi.block_until_ready()
    times = np.empty(num_queries)
    for i in range(num_queries):
        src = np.array([workload.source_of(i)])
        t0 = time.perf_counter()
        res = fora_fused(sdg, src, params, jax.random.PRNGKey(i),
                         num_walks=walk_budget)
        res.pi.block_until_ready()
        times[i] = time.perf_counter() - t0
    emit("fora/sharded_per_query", float(np.mean(times)) * 1e6,
         f"n={graph.n};shards={k};layout={sdg.layout};"
         f"walk_budget={res.walks_budget};measured={num_queries}")


def _run_powerlaw(n: int = 4000, num_queries: int = 64) -> None:
    """Fused serving on a power-law graph: the sliced-ELL substrate
    (DESIGN.md §8). The dense (n, k_max) table this shape implies is what
    blocked web-scale graphs before slicing; the row reports per-query time
    through the sliced table plus the dense-vs-sliced resident bytes."""
    rng = np.random.default_rng(0)
    src = np.concatenate([np.arange(1, n), rng.integers(0, n, 4 * n)])
    dst = np.concatenate([np.zeros(n - 1, np.int64),
                          rng.integers(0, n, 4 * n)])
    graph = Graph.from_edges(n, src, dst, name="powerlaw-hot")
    dg = graph.device()
    params = ForaParams(alpha=0.2, epsilon=0.5, delta=1e-2, p_f=1e-2)
    workload = PprWorkload(graph, num_queries=num_queries, seed=0)
    ex = ForaExecutor(workload, params, fused=True)
    us = float(np.mean(ex(list(range(num_queries))).times)) * 1e6
    dense_mib = graph.ell_in_dense_nbytes() / 2**20
    sliced_mib = dg.ell_nbytes / 2**20
    emit("fora/powerlaw_fused_per_query", us,
         f"n={n};m={graph.m};layout={dg.layout};W={dg.ell_width};"
         f"sliced_MiB={sliced_mib:.2f};dense_MiB={dense_mib:.2f};"
         f"walk_budget={ex._num_walks}")


if __name__ == "__main__":
    run()
