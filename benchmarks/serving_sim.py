"""Serving-runtime simulation: deadline hit-rate and core-hours versus
static Lemma-2 provisioning, under Poisson arrivals and injected failures.

Fully seeded and virtual-time — every number here is DETERMINISTIC (bit
identical on replay), which is what lets the CI tolerance gate treat the
quality metrics like perf rows. All gated rows are "lower is better"
(miss rate, lateness, core-hour ratio), offset by +1 so a zero-baseline row
stays gateable (tools/bench_compare.py skips rows with baseline <= 0):

* ``serving/miss_rate_pct_p1``      — 100*(1-hit_rate) + 1
* ``serving/lateness_p99_ms_p1``    — p99 lateness + 1 (ms)
* ``serving/core_hours_vs_lemma2_pct`` — 100 * runtime/static core-seconds
* ``serving/failure_unfinished_p1`` — unfinished jobs in the failure run + 1
* ``serving/sim_wall_us``           — wall time of one simulation drive
* ``serving/chaos_miss_rate_pct_p1``   — miss rate under the chaos leg + 1
* ``serving/chaos_core_hours_vs_clean_pct`` — chaos core-s / failure-free
  anchor core-s (same workload, no faults) x 100 — what the faults cost
* ``serving/chaos_unfinished_p1``   — unfinished jobs under chaos + 1

``--check`` mode (the CI smoke leg) re-runs the same seeded scenario twice
and asserts: deterministic replay, >= 95% deadline hit-rate, total
core-hours strictly below static per-job Lemma-2 provisioning, and the
failure-injection run completing every job via readmission (no job loss).
``--chaos`` mode (DESIGN.md §12) drives the WAL-attached chaos scenario —
device failure + lane slowdowns + process crashes with recovery — and
asserts: deterministic replay, crash-transparency (records bit-identical
to the same chaos scenario run without crashes), every job completed,
at least one recovery and at least one straggler re-issue.

    PYTHONPATH=src python -m benchmarks.serving_sim [--check] [--chaos]
"""

from __future__ import annotations

import argparse
import tempfile
import time

from repro.ft.chaos import ChaosSchedule, ChaosSpec, drive_with_crashes
from repro.serving import (CorePool, ServingConfig, ServingReport,
                           ServingRuntime, SimJobExecutor, WriteAheadLog)

from .common import emit

SEED = 0
NUM_JOBS = 24
RATE = 0.6                 # jobs/second
QUERIES = (150, 400)
DEADLINE = (6.0, 12.0)
POOL_CORES = 48
# failure scenario: tight pool + losing 9 of 12 devices overcommits the
# grants, forcing shed_plan cuts and per-job readmission (not just a
# capacity note in the rescale event)
FAIL_POOL_CORES = 12
FAIL_RATE = 0.8
FAIL_QUERIES = (250, 500)
FAIL_DEADLINE = (5.0, 8.0)
FAILURES = {4.0: [0, 1, 2, 3, 4, 5, 6, 7], 9.0: [8]}
# chaos scenario (DESIGN.md §12): one device failure + two lane slowdowns
# + two process crashes, with spares so straggler re-issue can fire
CHAOS_SEED = 7
CHAOS_POOL = 32
CHAOS_JOBS = 12
CHAOS_RATE = 0.7
CHAOS_QUERIES = (120, 300)
CHAOS_DEADLINE = (6.0, 10.0)
CHAOS_SNAPSHOT_EVERY = 16
CHAOS_SPARES = 0.1
CHAOS_SPEC = "seed=7,failures=1,slowdowns=2,horizon=18,slow_factor=2.5"
CHAOS_CRASH_AT = (25, 60)


def _drive(pool_cores: int, *, failures: dict | None = None,
           num_jobs: int = NUM_JOBS, seed: int = SEED,
           rate: float = RATE, queries: tuple = QUERIES,
           deadline: tuple = DEADLINE) -> ServingReport:
    rt = ServingRuntime(
        CorePool.of(pool_cores),
        lambda job_id, nq, sd: SimJobExecutor(mean=0.05, cv=0.3, seed=sd),
        ServingConfig(scaling_factor=0.9, sample_frac=0.05))
    rt.submit_poisson(num_jobs, rate, queries=queries, deadline=deadline,
                      seed=seed)
    if failures:
        rt.inject_failures(failures)
    return rt.run()


def _drive_failure_run() -> ServingReport:
    return _drive(FAIL_POOL_CORES, failures=FAILURES, num_jobs=10,
                  rate=FAIL_RATE, queries=FAIL_QUERIES,
                  deadline=FAIL_DEADLINE)


def _chaos_factory(job_id: int, nq: int, sd: int) -> SimJobExecutor:
    return SimJobExecutor(mean=0.05, cv=0.3, seed=sd)


def _chaos_runtime(wal_dir: str | None) -> ServingRuntime:
    """The chaos workload: spares so straggler re-issue can fire, WAL
    attached when a directory is given (crash legs need one; the clean
    anchor passes None)."""
    rt = ServingRuntime(
        CorePool.of(CHAOS_POOL, spares_fraction=CHAOS_SPARES),
        _chaos_factory,
        ServingConfig(scaling_factor=0.9, sample_frac=0.05,
                      stragglers=True))
    if wal_dir is not None:
        rt.attach_wal(WriteAheadLog(wal_dir, fsync=False),
                      snapshot_every=CHAOS_SNAPSHOT_EVERY)
    rt.submit_poisson(CHAOS_JOBS, CHAOS_RATE, queries=CHAOS_QUERIES,
                      deadline=CHAOS_DEADLINE, seed=CHAOS_SEED)
    sched = ChaosSchedule.from_spec(ChaosSpec.parse(CHAOS_SPEC), CHAOS_POOL)
    sched.apply(rt)
    return rt


def _drive_chaos() -> tuple[ServingReport, list, ServingRuntime]:
    """Faults + crashes + recovery; fsync off — the benchmark measures the
    scheduler, not the disk."""
    with tempfile.TemporaryDirectory() as wal_dir:
        rt = _chaos_runtime(wal_dir)
        return drive_with_crashes(rt, wal_dir, _chaos_factory,
                                  CHAOS_CRASH_AT, fsync=False)


def _drive_chaos_uncrashed() -> ServingReport:
    """Same workload and fault schedule, no process crashes — the report
    the crashed-and-recovered run must reproduce bit-for-bit."""
    return _chaos_runtime(None).run()


def _drive_chaos_anchor() -> ServingReport:
    """Same workload, NO faults at all — the core-hours denominator."""
    rt = ServingRuntime(
        CorePool.of(CHAOS_POOL, spares_fraction=CHAOS_SPARES),
        _chaos_factory,
        ServingConfig(scaling_factor=0.9, sample_frac=0.05,
                      stragglers=True))
    rt.submit_poisson(CHAOS_JOBS, CHAOS_RATE, queries=CHAOS_QUERIES,
                      deadline=CHAOS_DEADLINE, seed=CHAOS_SEED)
    return rt.run()


def run() -> None:
    t0 = time.perf_counter()
    rep = _drive(POOL_CORES)
    wall_us = (time.perf_counter() - t0) * 1e6

    miss_pct = 100.0 * (1.0 - rep.hit_rate)
    ratio_pct = 100.0 * rep.core_seconds / rep.lemma2_core_seconds
    emit("serving/miss_rate_pct_p1", miss_pct + 1.0,
         f"hit_rate={rep.hit_rate:.3f};jobs={len(rep.records)}")
    emit("serving/lateness_p99_ms_p1",
         rep.lateness_quantile(0.99) * 1e3 + 1.0,
         f"p50_ms={rep.lateness_quantile(0.5) * 1e3:.1f}")
    emit("serving/core_hours_vs_lemma2_pct", ratio_pct,
         f"core_s={rep.core_seconds:.1f};lemma2={rep.lemma2_core_seconds:.1f}")
    emit("serving/sim_wall_us", wall_us, f"end_t={rep.end_time:.1f}s")

    frep = _drive_failure_run()
    unfinished = len(frep.records) - frep.completed
    emit("serving/failure_unfinished_p1", unfinished + 1.0,
         f"done={frep.completed};extended={frep.extended};"
         f"degraded={frep.degraded}")

    crep, infos, _ = _drive_chaos()
    anchor = _drive_chaos_anchor()
    chaos_miss = 100.0 * (1.0 - crep.hit_rate)
    chaos_unfinished = len(crep.records) - crep.completed
    emit("serving/chaos_miss_rate_pct_p1", chaos_miss + 1.0,
         f"hit_rate={crep.hit_rate:.3f};recoveries={len(infos)}")
    emit("serving/chaos_core_hours_vs_clean_pct",
         100.0 * crep.core_seconds / anchor.core_seconds,
         f"chaos_core_s={crep.core_seconds:.1f};"
         f"clean_core_s={anchor.core_seconds:.1f}")
    emit("serving/chaos_unfinished_p1", chaos_unfinished + 1.0,
         f"done={crep.completed};extended={crep.extended};"
         f"degraded={crep.degraded}")


def check() -> None:
    """CI smoke assertions over the same seeded scenario (ISSUE 4)."""
    rep_a = _drive(POOL_CORES)
    rep_b = _drive(POOL_CORES)
    assert rep_a == rep_b, "seeded serving sim is not replay-deterministic"
    assert rep_a.hit_rate >= 0.95, \
        f"deadline hit-rate {rep_a.hit_rate:.3f} < 0.95"
    assert rep_a.core_seconds < rep_a.lemma2_core_seconds, (
        f"runtime core-hours {rep_a.core_seconds:.1f} not below static "
        f"Lemma-2 {rep_a.lemma2_core_seconds:.1f}")
    frep = _drive_failure_run()
    assert frep.completed == len(frep.records), (
        f"failure run lost {len(frep.records) - frep.completed} job(s) "
        "instead of readmitting")
    assert frep.rejected == 0
    assert frep.extended > 0, "failure run never exercised readmission"
    print(f"serving_sim --check OK: hit_rate={rep_a.hit_rate:.3f} "
          f"core_s={rep_a.core_seconds:.1f} < "
          f"lemma2={rep_a.lemma2_core_seconds:.1f}; failure run "
          f"done={frep.completed}/{len(frep.records)} "
          f"(extended={frep.extended}, degraded={frep.degraded})")


def check_chaos() -> None:
    """CI chaos smoke (ISSUE 6): crash-transparency + no job loss."""
    crep, infos, rt = _drive_chaos()
    crep2, infos2, _ = _drive_chaos()
    assert crep == crep2 and len(infos) == len(infos2), \
        "chaos scenario is not replay-deterministic"
    assert len(infos) >= 1, (
        f"crash points {CHAOS_CRASH_AT} never fired — trace drained "
        f"before event {min(CHAOS_CRASH_AT)}; retune the scenario")
    uncrashed = _drive_chaos_uncrashed()
    assert crep.records == uncrashed.records, (
        "crashed-and-recovered chaos run diverged from the same scenario "
        "without crashes — recovery is not transparent")
    assert crep.completed == len(crep.records), (
        f"chaos run lost {len(crep.records) - crep.completed} accepted "
        "job(s) — the durability contract is broken")
    n_straggler = len(rt.controller.straggler_events)
    assert n_straggler >= 1, (
        "chaos slowdowns never triggered a straggler re-issue — "
        "mitigation is not wired")
    print(f"serving_sim --chaos OK: done={crep.completed}/"
          f"{len(crep.records)} recoveries={len(infos)} "
          f"straggler_reissues={n_straggler} "
          f"hit_rate={crep.hit_rate:.3f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="assert the CI smoke criteria instead of emitting "
                         "benchmark rows")
    ap.add_argument("--chaos", action="store_true",
                    help="assert the chaos-harness smoke criteria "
                         "(crash-transparency, no job loss)")
    args = ap.parse_args()
    if args.check:
        check()
    elif args.chaos:
        check_chaos()
    else:
        run()
