"""Serving-runtime simulation: deadline hit-rate and core-hours versus
static Lemma-2 provisioning, under Poisson arrivals and injected failures.

Fully seeded and virtual-time — every number here is DETERMINISTIC (bit
identical on replay), which is what lets the CI tolerance gate treat the
quality metrics like perf rows. All gated rows are "lower is better"
(miss rate, lateness, core-hour ratio), offset by +1 so a zero-baseline row
stays gateable (tools/bench_compare.py skips rows with baseline <= 0):

* ``serving/miss_rate_pct_p1``      — 100*(1-hit_rate) + 1
* ``serving/lateness_p99_ms_p1``    — p99 lateness + 1 (ms)
* ``serving/core_hours_vs_lemma2_pct`` — 100 * runtime/static core-seconds
* ``serving/failure_unfinished_p1`` — unfinished jobs in the failure run + 1
* ``serving/sim_wall_us``           — wall time of one simulation drive
* ``serving/chaos_miss_rate_pct_p1``   — miss rate under the chaos leg + 1
* ``serving/chaos_core_hours_vs_clean_pct`` — chaos core-s / failure-free
  anchor core-s (same workload, no faults) x 100 — what the faults cost
* ``serving/chaos_unfinished_p1``   — unfinished jobs under chaos + 1
* ``serving/engine_qps``            — engine-mode µs per answered query on
  the burst trace (1e6 * end_time / answered; QPS + speedup vs. the
  chunked path on the SAME trace in the note)
* ``serving/engine_lane_util``      — engine lane idle percentage + 1
  (time-weighted over the controller's occupancy samples)
* ``serving/cold_start_pre_core_s`` — preprocess core-seconds billed on a
  daemon first start (compile surcharge inside the c-core reservation)
* ``serving/warm_start_pre_core_s`` — same trace with a warm persistent
  compilation cache (surcharge waived) — the gap is the cold-start saving
* ``serving/churn_miss_rate_pct_p1`` — miss rate under the seeded
  graph-mutation stream + 1 (must match the failure-free anchor)
* ``serving/churn_refresh_vs_rebuild_pct`` — incremental-refresh core-s as
  a percentage of the counterfactual full-rebuild core-s (DESIGN.md §16)

``--check`` mode (the CI smoke leg) re-runs the same seeded scenario twice
and asserts: deterministic replay, >= 95% deadline hit-rate, total
core-hours strictly below static per-job Lemma-2 provisioning, the
failure-injection run completing every job via readmission (no job loss),
and the warm cold-start contract: a warm-compilation-cache second start
bills measurably fewer preprocess core-seconds than the first, while
staying bit-identical to a run that never had a compile surcharge.
``--check --engine`` drives the burst trace through both paths and asserts
the engine headline: deterministic replay, 100% SLA hit-rate preserved,
and >= 1.5x queries/sec over the chunked path (ISSUE 8).
``--check --mutation-rate R`` drives the anchor workload under a seeded
mutation stream at R batches/s and asserts the churn gate (ISSUE 10):
deterministic replay, the anchor SLA hit-rate fully sustained, incremental
refresh below 25% of the full-rebuild core-seconds, and the cache TTL
auto-tuned from the observed update cadence.
``--chaos`` mode (DESIGN.md §12) drives the WAL-attached chaos scenario —
device failure + lane slowdowns + process crashes with recovery — and
asserts: deterministic replay, crash-transparency (records bit-identical
to the same chaos scenario run without crashes), every job completed,
at least one recovery and at least one straggler re-issue (``--engine``
swaps the straggler assertion for lane-occupancy coverage — engine mode
has no slot boundaries to re-issue at).

    PYTHONPATH=src python -m benchmarks.serving_sim [--check] [--chaos]
                                                    [--engine]
"""

from __future__ import annotations

import argparse
import tempfile
import time

from repro.ft.chaos import ChaosSchedule, ChaosSpec, drive_with_crashes
from repro.index import ResultCache
from repro.serving import (CorePool, ServingConfig, ServingReport,
                           ServingRuntime, SimJobExecutor, WriteAheadLog)

from .common import emit

SEED = 0
NUM_JOBS = 24
RATE = 0.6                 # jobs/second
QUERIES = (150, 400)
DEADLINE = (6.0, 12.0)
POOL_CORES = 48
# failure scenario: tight pool + losing 9 of 12 devices overcommits the
# grants, forcing shed_plan cuts and per-job readmission (not just a
# capacity note in the rescale event)
FAIL_POOL_CORES = 12
FAIL_RATE = 0.8
FAIL_QUERIES = (250, 500)
FAIL_DEADLINE = (5.0, 8.0)
FAILURES = {4.0: [0, 1, 2, 3, 4, 5, 6, 7], 9.0: [8]}
# chaos scenario (DESIGN.md §12): one device failure + two lane slowdowns
# + two process crashes, with spares so straggler re-issue can fire
CHAOS_SEED = 7
CHAOS_POOL = 32
CHAOS_JOBS = 12
CHAOS_RATE = 0.7
CHAOS_QUERIES = (120, 300)
CHAOS_DEADLINE = (6.0, 10.0)
CHAOS_SNAPSHOT_EVERY = 16
CHAOS_SPARES = 0.1
CHAOS_SPEC = "seed=7,failures=1,slowdowns=2,horizon=18,slow_factor=2.5"
CHAOS_CRASH_AT = (25, 60)
# engine headline scenario: a burst (high arrival rate) of mixed-deadline
# jobs. The chunked planner stretches every job across its own deadline
# window (Alg. 2 sizes ell to land at T*d), so burst throughput is
# deadline-bound; the engine's EDF lane pool is work-conserving and drains
# the same trace as fast as the lanes allow.
ENGINE_JOBS = 16
ENGINE_RATE = 3.0
# daemon cold-start scenario (DESIGN.md §15): the first admitted job eats
# the fused-executable compile inside its c-core reservation; a
# warm persistent compilation cache (second daemon start) waives it
COLD_COMPILE_S = 2.0
# churn scenario (DESIGN.md §16): the anchor workload under a seeded
# graph-mutation stream — each batch bumps graph_version, feeds the cache's
# TTL tuner and books incremental-refresh vs full-rebuild core-seconds
CHURN_MUTATIONS = 10
CHURN_RATE = 0.5           # mutation batches/second
CHURN_GRAPH_N = 4000
CHURN_AFFECTED_FRAC = 0.02
CHURN_BUDGET = 60          # per-batch refresh budget (nodes)
CHURN_NODE_COST = 0.002    # core-seconds per redrawn node
CHURN_TTL_FACTOR = 4.0     # cache TTL = factor x observed update cadence


def _drive(pool_cores: int, *, failures: dict | None = None,
           num_jobs: int = NUM_JOBS, seed: int = SEED,
           rate: float = RATE, queries: tuple = QUERIES,
           deadline: tuple = DEADLINE, engine: bool = False,
           lane_pool: int = 0, cold_compile_s: float = 0.0,
           warm_start: bool = False,
           return_runtime: bool = False):
    rt = ServingRuntime(
        CorePool.of(pool_cores),
        lambda job_id, nq, sd: SimJobExecutor(mean=0.05, cv=0.3, seed=sd),
        ServingConfig(scaling_factor=0.9, sample_frac=0.05,
                      engine=engine, lane_pool=lane_pool,
                      cold_compile_s=cold_compile_s, warm_start=warm_start))
    rt.submit_poisson(num_jobs, rate, queries=queries, deadline=deadline,
                      seed=seed)
    if failures:
        rt.inject_failures(failures)
    rep = rt.run()
    return (rep, rt) if return_runtime else rep


def _drive_engine_pair() -> tuple[ServingReport, ServingReport,
                                  ServingRuntime]:
    """Chunked and engine reports for the SAME burst trace (same seeds,
    same arrivals, same pool) — the queries/sec-at-fixed-SLA headline."""
    kw = dict(num_jobs=ENGINE_JOBS, rate=ENGINE_RATE)
    chunk = _drive(POOL_CORES, **kw)
    erep, ert = _drive(POOL_CORES, engine=True, return_runtime=True, **kw)
    return chunk, erep, ert


def _answered(rep: ServingReport) -> int:
    return sum(r.num_queries for r in rep.records if r.state == "done")


def _qps(rep: ServingReport) -> float:
    return _answered(rep) / rep.end_time if rep.end_time > 0 else 0.0


def _lane_utilisation(events: list[dict], end_time: float) -> float:
    """Time-weighted busy-lane fraction over [first sample, end_time]."""
    if not events or end_time <= 0:
        return 0.0
    util = 0.0
    for cur, nxt in zip(events, events[1:]):
        util += cur["busy"] / max(1, cur["lanes"]) * (nxt["t"] - cur["t"])
    last = events[-1]
    util += (last["busy"] / max(1, last["lanes"])
             * max(0.0, end_time - last["t"]))
    return util / end_time


def _drive_churn(mutation_rate: float = CHURN_RATE
                 ) -> tuple[ServingReport, ServingRuntime]:
    """The anchor workload plus a seeded mutation stream (DESIGN.md §16):
    graph updates arrive as heap events interleaved with the jobs, each
    bumping the live graph_version and booking the incremental-invalidation
    ledgers the churn gate reads."""
    rt = ServingRuntime(
        CorePool.of(POOL_CORES),
        lambda job_id, nq, sd: SimJobExecutor(mean=0.05, cv=0.3, seed=sd),
        ServingConfig(scaling_factor=0.9, sample_frac=0.05),
        cache=ResultCache(4096, ttl_update_factor=CHURN_TTL_FACTOR))
    rt.submit_poisson(NUM_JOBS, RATE, queries=QUERIES, deadline=DEADLINE,
                      seed=SEED)
    rt.schedule_mutations(CHURN_MUTATIONS, mutation_rate, seed=SEED + 1,
                          graph_n=CHURN_GRAPH_N,
                          affected_frac=CHURN_AFFECTED_FRAC,
                          refresh_budget=CHURN_BUDGET,
                          node_cost=CHURN_NODE_COST)
    return rt.run(), rt


def _drive_failure_run() -> ServingReport:
    return _drive(FAIL_POOL_CORES, failures=FAILURES, num_jobs=10,
                  rate=FAIL_RATE, queries=FAIL_QUERIES,
                  deadline=FAIL_DEADLINE)


def _chaos_factory(job_id: int, nq: int, sd: int) -> SimJobExecutor:
    return SimJobExecutor(mean=0.05, cv=0.3, seed=sd)


def _chaos_runtime(wal_dir: str | None,
                   engine: bool = False) -> ServingRuntime:
    """The chaos workload: spares so straggler re-issue can fire, WAL
    attached when a directory is given (crash legs need one; the clean
    anchor passes None)."""
    rt = ServingRuntime(
        CorePool.of(CHAOS_POOL, spares_fraction=CHAOS_SPARES),
        _chaos_factory,
        ServingConfig(scaling_factor=0.9, sample_frac=0.05,
                      stragglers=True, engine=engine))
    if wal_dir is not None:
        rt.attach_wal(WriteAheadLog(wal_dir, fsync=False),
                      snapshot_every=CHAOS_SNAPSHOT_EVERY)
    rt.submit_poisson(CHAOS_JOBS, CHAOS_RATE, queries=CHAOS_QUERIES,
                      deadline=CHAOS_DEADLINE, seed=CHAOS_SEED)
    sched = ChaosSchedule.from_spec(ChaosSpec.parse(CHAOS_SPEC), CHAOS_POOL)
    sched.apply(rt)
    return rt


def _drive_chaos(engine: bool = False) -> tuple[ServingReport, list,
                                                ServingRuntime]:
    """Faults + crashes + recovery; fsync off — the benchmark measures the
    scheduler, not the disk."""
    with tempfile.TemporaryDirectory() as wal_dir:
        rt = _chaos_runtime(wal_dir, engine=engine)
        return drive_with_crashes(rt, wal_dir, _chaos_factory,
                                  CHAOS_CRASH_AT, fsync=False)


def _drive_chaos_uncrashed(engine: bool = False) -> ServingReport:
    """Same workload and fault schedule, no process crashes — the report
    the crashed-and-recovered run must reproduce bit-for-bit."""
    return _chaos_runtime(None, engine=engine).run()


def _drive_chaos_anchor() -> ServingReport:
    """Same workload, NO faults at all — the core-hours denominator."""
    rt = ServingRuntime(
        CorePool.of(CHAOS_POOL, spares_fraction=CHAOS_SPARES),
        _chaos_factory,
        ServingConfig(scaling_factor=0.9, sample_frac=0.05,
                      stragglers=True))
    rt.submit_poisson(CHAOS_JOBS, CHAOS_RATE, queries=CHAOS_QUERIES,
                      deadline=CHAOS_DEADLINE, seed=CHAOS_SEED)
    return rt.run()


def run() -> None:
    t0 = time.perf_counter()
    rep = _drive(POOL_CORES)
    wall_us = (time.perf_counter() - t0) * 1e6

    miss_pct = 100.0 * (1.0 - rep.hit_rate)
    ratio_pct = 100.0 * rep.core_seconds / rep.lemma2_core_seconds
    emit("serving/miss_rate_pct_p1", miss_pct + 1.0,
         f"hit_rate={rep.hit_rate:.3f};jobs={len(rep.records)}")
    emit("serving/lateness_p99_ms_p1",
         rep.lateness_quantile(0.99) * 1e3 + 1.0,
         f"p50_ms={rep.lateness_quantile(0.5) * 1e3:.1f}")
    emit("serving/core_hours_vs_lemma2_pct", ratio_pct,
         f"core_s={rep.core_seconds:.1f};lemma2={rep.lemma2_core_seconds:.1f}")
    emit("serving/sim_wall_us", wall_us, f"end_t={rep.end_time:.1f}s")

    frep = _drive_failure_run()
    unfinished = len(frep.records) - frep.completed
    emit("serving/failure_unfinished_p1", unfinished + 1.0,
         f"done={frep.completed};extended={frep.extended};"
         f"degraded={frep.degraded}")

    crep, infos, _ = _drive_chaos()
    anchor = _drive_chaos_anchor()
    chaos_miss = 100.0 * (1.0 - crep.hit_rate)
    chaos_unfinished = len(crep.records) - crep.completed
    emit("serving/chaos_miss_rate_pct_p1", chaos_miss + 1.0,
         f"hit_rate={crep.hit_rate:.3f};recoveries={len(infos)}")
    emit("serving/chaos_core_hours_vs_clean_pct",
         100.0 * crep.core_seconds / anchor.core_seconds,
         f"chaos_core_s={crep.core_seconds:.1f};"
         f"clean_core_s={anchor.core_seconds:.1f}")
    emit("serving/chaos_unfinished_p1", chaos_unfinished + 1.0,
         f"done={crep.completed};extended={crep.extended};"
         f"degraded={crep.degraded}")

    chunk, erep, ert = _drive_engine_pair()
    eng_qps, chk_qps = _qps(erep), _qps(chunk)
    emit("serving/engine_qps",
         1e6 * erep.end_time / max(1, _answered(erep)),
         f"qps={eng_qps:.1f};chunked_qps={chk_qps:.1f};"
         f"speedup={eng_qps / max(chk_qps, 1e-12):.2f}x;"
         f"hit_rate={erep.hit_rate:.3f}")
    util = _lane_utilisation(ert.controller.occupancy_events, erep.end_time)
    emit("serving/engine_lane_util", 100.0 * (1.0 - util) + 1.0,
         f"busy_frac={util:.3f};lanes={ert.engine.lanes};"
         f"samples={len(ert.controller.occupancy_events)}")

    churn_rep, churn_rt = _drive_churn()
    churn_miss = 100.0 * (1.0 - churn_rep.hit_rate)
    refresh_pct = (100.0 * churn_rt.refresh_core_s
                   / max(churn_rt.rebuild_core_s, 1e-12))
    emit("serving/churn_miss_rate_pct_p1", churn_miss + 1.0,
         f"hit_rate={churn_rep.hit_rate:.3f};"
         f"mutations={churn_rt.mutations_applied};"
         f"graph_v={churn_rt.graph_version}")
    emit("serving/churn_refresh_vs_rebuild_pct", refresh_pct,
         f"refresh_core_s={churn_rt.refresh_core_s:.2f};"
         f"rebuild_core_s={churn_rt.rebuild_core_s:.2f};"
         f"pending={churn_rt.pending_refresh};"
         f"auto_ttl={churn_rt.cache.ttl:.2f}")

    # daemon cold start vs warm compilation cache (DESIGN.md §15): identical
    # trace, only the compile surcharge waiver differs — the gap is exactly
    # what the persistent compilation cache stops billing against deadlines
    _, cold_rt = _drive(POOL_CORES, cold_compile_s=COLD_COMPILE_S,
                        return_runtime=True)
    _, warm_rt = _drive(POOL_CORES, cold_compile_s=COLD_COMPILE_S,
                        warm_start=True, return_runtime=True)
    emit("serving/cold_start_pre_core_s", cold_rt.pre_core_s,
         f"compile_s={COLD_COMPILE_S};c={cold_rt.cfg.preprocess_cores}")
    emit("serving/warm_start_pre_core_s", warm_rt.pre_core_s,
         f"saved_core_s={cold_rt.pre_core_s - warm_rt.pre_core_s:.2f}")


def check() -> None:
    """CI smoke assertions over the same seeded scenario (ISSUE 4)."""
    rep_a = _drive(POOL_CORES)
    rep_b = _drive(POOL_CORES)
    assert rep_a == rep_b, "seeded serving sim is not replay-deterministic"
    assert rep_a.hit_rate >= 0.95, \
        f"deadline hit-rate {rep_a.hit_rate:.3f} < 0.95"
    assert rep_a.core_seconds < rep_a.lemma2_core_seconds, (
        f"runtime core-hours {rep_a.core_seconds:.1f} not below static "
        f"Lemma-2 {rep_a.lemma2_core_seconds:.1f}")
    frep = _drive_failure_run()
    assert frep.completed == len(frep.records), (
        f"failure run lost {len(frep.records) - frep.completed} job(s) "
        "instead of readmitting")
    assert frep.rejected == 0
    assert frep.extended > 0, "failure run never exercised readmission"
    # warm cold-start (DESIGN.md §15): the second daemon start — warm
    # persistent compilation cache — must bill measurably fewer preprocess
    # core-seconds than the first, and be indistinguishable from a runtime
    # that never had a compile surcharge at all
    warm_rep, warm_rt = _drive(POOL_CORES, cold_compile_s=COLD_COMPILE_S,
                               warm_start=True, return_runtime=True)
    cold_rep, cold_rt = _drive(POOL_CORES, cold_compile_s=COLD_COMPILE_S,
                               return_runtime=True)
    assert warm_rep == rep_a, (
        "warm-start run diverged from the no-surcharge baseline — the "
        "waived compile must leave the trace bit-identical")
    saved = cold_rt.pre_core_s - warm_rt.pre_core_s
    floor = 0.9 * cold_rt.cfg.preprocess_cores * COLD_COMPILE_S
    assert saved >= floor, (
        f"warm start saved only {saved:.2f} preprocess core-s — expected "
        f">= {floor:.2f} (compile surcharge {COLD_COMPILE_S}s on "
        f"{cold_rt.cfg.preprocess_cores} core(s))")
    assert cold_rep.hit_rate >= 0.95, (
        f"cold-start run hit-rate {cold_rep.hit_rate:.3f} < 0.95 — the "
        "surcharge sank the first job's deadline")
    print(f"serving_sim --check OK: hit_rate={rep_a.hit_rate:.3f} "
          f"core_s={rep_a.core_seconds:.1f} < "
          f"lemma2={rep_a.lemma2_core_seconds:.1f}; failure run "
          f"done={frep.completed}/{len(frep.records)} "
          f"(extended={frep.extended}, degraded={frep.degraded}); "
          f"warm start saved {saved:.2f} preprocess core-s")


def check_engine() -> None:
    """CI engine smoke (ISSUE 8): the queries/sec-at-fixed-SLA headline —
    deterministic replay, 100% SLA preserved, >= 1.5x over chunked."""
    chunk, erep, ert = _drive_engine_pair()
    erep2 = _drive(POOL_CORES, engine=True, num_jobs=ENGINE_JOBS,
                   rate=ENGINE_RATE)
    assert erep == erep2, "engine-mode serving sim is not replay-" \
        "deterministic"
    assert erep.completed == len(erep.records), (
        f"engine run lost {len(erep.records) - erep.completed} job(s)")
    assert erep.hit_rate == 1.0, (
        f"engine hit-rate {erep.hit_rate:.3f} != 1.0 — the speedup must "
        "not cost SLA")
    speedup = _qps(erep) / max(_qps(chunk), 1e-12)
    assert speedup >= 1.5, (
        f"engine {_qps(erep):.1f} q/s vs chunked {_qps(chunk):.1f} q/s "
        f"= {speedup:.2f}x < 1.5x target")
    util = _lane_utilisation(ert.controller.occupancy_events, erep.end_time)
    assert util > 0.0, "no lane occupancy was accounted"
    print(f"serving_sim --check --engine OK: engine {_qps(erep):.1f} q/s "
          f"vs chunked {_qps(chunk):.1f} q/s ({speedup:.2f}x >= 1.5x), "
          f"hit_rate={erep.hit_rate:.3f}, busy_frac={util:.3f}")


def check_churn(mutation_rate: float = CHURN_RATE) -> None:
    """CI churn smoke (ISSUE 10): the anchor workload under a live seeded
    mutation stream — deterministic replay, the anchor SLA hit-rate fully
    sustained, incremental refresh < 25% of full-rebuild core-seconds, and
    the cache TTL actually tuned from the observed update cadence."""
    anchor = _drive(POOL_CORES)
    rep_a, rt_a = _drive_churn(mutation_rate)
    rep_b, rt_b = _drive_churn(mutation_rate)
    assert rep_a == rep_b and rt_a.refresh_core_s == rt_b.refresh_core_s, \
        "churn serving sim is not replay-deterministic"
    assert rt_a.mutations_applied == CHURN_MUTATIONS, (
        f"only {rt_a.mutations_applied}/{CHURN_MUTATIONS} mutation batches "
        "fired — the stream outlived the trace; lower CHURN_RATE")
    assert rt_a.graph_version == CHURN_MUTATIONS
    assert rep_a.hit_rate >= anchor.hit_rate, (
        f"churn hit-rate {rep_a.hit_rate:.3f} below the failure-free "
        f"anchor {anchor.hit_rate:.3f} — incremental invalidation must "
        "not cost SLA")
    assert rt_a.rebuild_core_s > 0.0
    ratio = rt_a.refresh_core_s / rt_a.rebuild_core_s
    assert ratio < 0.25, (
        f"incremental refresh spent {100 * ratio:.1f}% of the full-rebuild "
        "core-seconds — >= 25% defeats the point of deltas")
    assert rt_a.cache.ttl is not None, (
        "cache TTL never auto-tuned — note_update is not wired into the "
        "mutation path")
    print(f"serving_sim --check --mutation-rate OK: "
          f"hit_rate={rep_a.hit_rate:.3f} >= anchor {anchor.hit_rate:.3f}; "
          f"{rt_a.mutations_applied} batches -> graph v{rt_a.graph_version}; "
          f"refresh/rebuild = {100 * ratio:.1f}% < 25%; "
          f"auto_ttl={rt_a.cache.ttl:.2f}s "
          f"(pending_refresh={rt_a.pending_refresh})")


def check_chaos(engine: bool = False) -> None:
    """CI chaos smoke (ISSUE 6): crash-transparency + no job loss. With
    ``engine=True`` (ISSUE 8) the same fault schedule drives the
    continuous-batching path; the straggler assertion is replaced by
    lane-occupancy coverage (no slot boundaries to re-issue at)."""
    crep, infos, rt = _drive_chaos(engine=engine)
    crep2, infos2, _ = _drive_chaos(engine=engine)
    assert crep == crep2 and len(infos) == len(infos2), \
        "chaos scenario is not replay-deterministic"
    assert len(infos) >= 1, (
        f"crash points {CHAOS_CRASH_AT} never fired — trace drained "
        f"before event {min(CHAOS_CRASH_AT)}; retune the scenario")
    uncrashed = _drive_chaos_uncrashed(engine=engine)
    assert crep.records == uncrashed.records, (
        "crashed-and-recovered chaos run diverged from the same scenario "
        "without crashes — recovery is not transparent")
    assert crep.completed == len(crep.records), (
        f"chaos run lost {len(crep.records) - crep.completed} accepted "
        "job(s) — the durability contract is broken")
    if engine:
        n_occ = len(rt.controller.occupancy_events)
        assert n_occ >= 1, (
            "engine chaos run recorded no lane-occupancy samples — "
            "occupancy accounting is not wired")
        print(f"serving_sim --chaos --engine OK: done={crep.completed}/"
              f"{len(crep.records)} recoveries={len(infos)} "
              f"occupancy_samples={n_occ} hit_rate={crep.hit_rate:.3f}")
        return
    n_straggler = len(rt.controller.straggler_events)
    assert n_straggler >= 1, (
        "chaos slowdowns never triggered a straggler re-issue — "
        "mitigation is not wired")
    print(f"serving_sim --chaos OK: done={crep.completed}/"
          f"{len(crep.records)} recoveries={len(infos)} "
          f"straggler_reissues={n_straggler} "
          f"hit_rate={crep.hit_rate:.3f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="assert the CI smoke criteria instead of emitting "
                         "benchmark rows")
    ap.add_argument("--chaos", action="store_true",
                    help="assert the chaos-harness smoke criteria "
                         "(crash-transparency, no job loss)")
    ap.add_argument("--engine", action="store_true",
                    help="with --check: assert the engine >= 1.5x QPS "
                         "headline; with --chaos: drive the chaos scenario "
                         "through the engine path")
    ap.add_argument("--mutation-rate", type=float, default=0.0,
                    help="with --check: assert the churn-leg criteria "
                         "(anchor SLA sustained, refresh < 25% of rebuild) "
                         "under a mutation stream at this rate (batches/s)")
    args = ap.parse_args()
    if args.check and args.engine:
        check_engine()
    elif args.check and args.mutation_rate > 0:
        check_churn(args.mutation_rate)
    elif args.check:
        check()
    elif args.chaos:
        check_chaos(engine=args.engine)
    else:
        run()
