"""Paper Fig. 2: required cores — D&A_REAL vs the Lemma-2 bound.

For each benchmark dataset and a grid of query counts X, runs the REAL
pipeline: measured per-query FORA times (JAX engine, wall clock) feed
D&A_REAL (Alg. 2) with the paper's per-dataset scaling factor d; the
Lemma-2 Hoeffding bound on the same sample is the baseline. Reports the
core reduction percentage (paper maxima: 62.50 / 66.67 / 38.89 / 73.68%
for Web-Stanford / DBLP / Pokec / LiveJournal).

Deadlines are set per dataset from the measured average query time
(T ~= X * t_avg / target_parallelism), mirroring the paper's choice of T
"based on the processing time per query".
"""

from __future__ import annotations

import numpy as np

from repro.core import InfeasibleDeadline, dna_real, fraction_sample_size
from repro.ppr import ForaExecutor, ForaParams, PprWorkload
from repro.ppr.datasets import TABLE1, synthesize

from .common import emit

# X grids: paper uses dataset-specific grids (its Fig. 2 x-axes); ours are
# scaled to the 1-core CPU container. --full widens them.
DEFAULT_GRID = (48, 96)
FULL_GRID = (64, 128, 192, 256)
TARGET_PARALLELISM = 4           # sets T so that ~4 cores would be busy
# Deadline floors keep preprocessing a small fraction of T (the paper's
# regime: X in the tens of thousands makes t_pre << T; at CPU scale we
# enforce it explicitly, t_pre <= T/8).
T_PRE_FLOOR = 8.0
T_MAX_FLOOR = 6.0


def run(scale: int = 512, grid=DEFAULT_GRID, epsilon: float = 0.5,
        seed: int = 0) -> None:
    for name, spec in TABLE1.items():
        graph = synthesize(spec, scale=scale, seed=seed)
        for X in grid:
            workload = PprWorkload(graph=graph, num_queries=X, seed=seed)
            executor = ForaExecutor(workload=workload,
                                    params=ForaParams(epsilon=epsilon))
            # §IV-A: web-stanford uses the (conservative) Eq.-1 sample size,
            # the larger graphs use 5% of the smallest query count. At CPU
            # scale Eq.1+FPC at X<=256 would sample nearly everything, so we
            # use a 25% fraction for web-stanford — same intent (its per-
            # source fluctuation is too heavy for a 5% probe), documented in
            # EXPERIMENTS.md.
            frac = 0.25 if name == "web-stanford" else 0.05
            s = fraction_sample_size(X, frac)
            # calibrate T from a steady-state probe of the sample queries
            # (second run — the first absorbs any residual jit variants)
            executor(list(range(s)))
            probe = executor(list(range(s)))
            deadline = max(X * probe.t_avg / TARGET_PARALLELISM,
                           probe.t_max * T_MAX_FLOOR,
                           probe.t_pre * T_PRE_FLOOR)
            # paper §III-A: on infeasibility "we prolong the duration to
            # ensure that a feasible solution can always be obtained"
            res = None
            for attempt in range(3):
                try:
                    res = dna_real(X, deadline, executor, max_cores=64,
                                   sample_size=s,
                                   scaling_factor=spec.scaling_factor_d)
                    break
                except InfeasibleDeadline:
                    deadline *= 2.0
            if res is None:
                emit(f"fig2/{name}/X{X}", 0.0,
                     f"rejected_after_extensions;T={deadline:.2f}s")
                continue
            emit(f"fig2/{name}/X{X}",
                 res.sample_stats.t_avg * 1e6,
                 f"cores={res.cores};lemma2={res.bounds.lemma2_cores};"
                 f"reduction={res.reduction_vs_lemma2_pct:.2f}%;"
                 f"T={deadline:.2f}s;d={spec.scaling_factor_d};"
                 f"completion={res.completion_time:.2f}s;"
                 f"accepted={res.accepted}")
            # paper's empirical finding, with +1 core slack for CPU
            # wall-clock jitter (single measurement, shared host)
            assert res.cores <= res.bounds.lemma2_cores + 1, \
                (f"D&A_REAL ({res.cores}) far above Lemma-2 baseline "
                 f"({res.bounds.lemma2_cores})")
