"""Paper Table I: benchmark dataset summary.

Builds the four synthetic stand-in graphs at 1/64 scale (offline container —
DESIGN.md §7) and reports generated vs paper-target order/size/type plus the
graph-build throughput.
"""

from __future__ import annotations

from repro.ppr.datasets import TABLE1, synthesize

from .common import emit, timed


def run(scale: int = 64) -> None:
    for name, spec in TABLE1.items():
        g, us = timed(synthesize, spec, scale, repeats=1)
        tn, tm = spec.scaled(scale)
        emit(f"table1/{name}", us,
             f"n={g.n};m={g.m};type={'dir' if g.directed else 'undir'};"
             f"paper_n={spec.n};paper_m={spec.m};scale=1/{scale};"
             f"avg_deg={g.avg_out_degree:.1f}")
