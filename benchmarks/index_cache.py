"""Walk-index & result-cache benchmark: cold vs warm serving (DESIGN.md §11).

Drives the SAME repeated-source trace through the serving runtime three
ways and reports the cache economics:

* **cold**    — no cache attached: bit-for-bit the PR-4 serving path (the
  regression anchor; ``--check`` asserts a capacity-0 cache run is
  IDENTICAL, so cache-aware admission cannot drift the uncached decisions);
* **warming** — a fresh cache attached: intra-run repeats (popular sources
  shared across concurrent jobs) are answered at arrival or shed at slot
  boundaries (late hits);
* **warm**    — the same trace replayed against the warmed cache: the
  steady state of repeated-query serving, where known answers bypass
  Lemma-1 admission and the core pool entirely.

All serving rows are deterministic (seeded virtual-time sim), so the CI
tolerance gate treats them like perf rows; lower is better, zero-able rows
are offset by +1 (tools/bench_compare.py skips baseline <= 0):

* ``index/warming_core_vs_cold_pct`` — 100 * warming/cold core-seconds
* ``index/warm_core_vs_cold_pct_p1`` — 100 * warm/cold core-seconds + 1
* ``index/warm_miss_rate_pct_p1``    — 100*(1 - SLA hit rate) + 1 (warm)
* ``index/sim_wall_us``              — wall time of the three drives

Plus two measured PPR rows (walk-index speedup on the real fused engine,
oracle path on CPU — same convention as kernels_bench):

* ``index/fused_live_us``  — fused query block, walks drawn live
* ``index/fused_index_us`` — same block served from a full-coverage index

``--check`` (the CI warm-cache smoke leg) asserts: deterministic replay,
cold == uncached bit-for-bit, warm SLA hit-rate == 100%, and warm
core-seconds <= 0.7x cold (the ISSUE-5 >= 30% reduction criterion).

    PYTHONPATH=src python -m benchmarks.index_cache [--check]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.index import ResultCache
from repro.serving import (CorePool, ServingConfig, ServingReport,
                           ServingRuntime, SimJobExecutor)

from .common import emit

SEED = 0
NUM_JOBS = 20
RATE = 0.5                   # jobs/second
QUERIES = (150, 300)
DEADLINE = (8.0, 14.0)
POOL_CORES = 48
POPULAR = 200                # shared hot-source pool (the repeat traffic)
REPEAT_FRAC = 0.7            # fraction of each job drawn from the hot pool
CACHE_CAPACITY = 4096


def _trace(seed: int = SEED) -> list[dict]:
    """Seeded repeated-source trace: each job mixes hot-pool sources
    (shared across jobs — the serving system's repeat traffic) with a
    per-job unique tail (fresh users)."""
    rng = np.random.default_rng(seed)
    rows = []
    t = 0.0
    fresh_base = 1 << 20     # unique-source id space, disjoint from the pool
    for j in range(NUM_JOBS):
        t += float(rng.exponential(1.0 / RATE))
        x = int(rng.integers(QUERIES[0], QUERIES[1] + 1))
        n_hot = int(round(x * REPEAT_FRAC))
        hot = rng.integers(0, POPULAR, size=n_hot)
        uniq = fresh_base + j * QUERIES[1] + np.arange(x - n_hot)
        sources = np.concatenate([hot, uniq])
        rng.shuffle(sources)
        rows.append({"at": t, "queries": x,
                     "deadline": float(rng.uniform(*DEADLINE)),
                     "seed": int(rng.integers(0, 1 << 31)),
                     "sources": [int(s) for s in sources]})
    return rows


def _drive(trace: list[dict],
           cache: ResultCache | None) -> ServingReport:
    rt = ServingRuntime(
        CorePool.of(POOL_CORES),
        lambda job_id, nq, sd: SimJobExecutor(mean=0.05, cv=0.3, seed=sd),
        ServingConfig(scaling_factor=0.9, sample_frac=0.05),
        cache=cache)
    rt.submit_trace(trace)
    return rt.run()


def _drive_legs(trace: list[dict]
                ) -> tuple[ServingReport, ServingReport, ServingReport]:
    cold = _drive(trace, None)
    cache = ResultCache(capacity=CACHE_CAPACITY)
    warming = _drive(trace, cache)
    warm = _drive(trace, cache)
    return cold, warming, warm


def _fused_rows() -> None:
    """Walk-index speedup on the real fused FORA engine (oracle path on
    CPU, the deployment path off-TPU — kernels_bench convention)."""
    from repro.ppr import ForaExecutor, ForaParams, PprWorkload, \
        small_test_graph

    from .common import timed

    graph = small_test_graph(n=512, avg_deg=8, seed=0)
    params = ForaParams(alpha=0.2, epsilon=0.5)
    qids = list(range(8))
    live = ForaExecutor(PprWorkload(graph, 64, seed=0), params, fused=True)
    live.warmup()
    _, us_live = timed(lambda: live.run_chunk(qids, seed=0))
    indexed = ForaExecutor(PprWorkload(graph, 64, seed=0), params,
                           fused=True, index_budget=1 << 14)
    indexed.warmup()    # builds the index outside the measured region
    assert indexed.index_coverage == 1.0, "index must cover the walk budget"
    _, us_idx = timed(lambda: indexed.run_chunk(qids, seed=0))
    emit("index/fused_live_us", us_live,
         f"walks={live._num_walks};n={graph.n}")
    emit("index/fused_index_us", us_idx,
         f"coverage={indexed.index_coverage:.2f};"
         f"speedup={us_live / max(us_idx, 1e-9):.2f}x")


def run() -> None:
    trace = _trace()
    t0 = time.perf_counter()
    cold, warming, warm = _drive_legs(trace)
    wall_us = (time.perf_counter() - t0) * 1e6

    warming_pct = 100.0 * warming.core_seconds / cold.core_seconds
    warm_pct = 100.0 * warm.core_seconds / cold.core_seconds
    emit("index/warming_core_vs_cold_pct", warming_pct,
         f"cold_core_s={cold.core_seconds:.1f};"
         f"warming_core_s={warming.core_seconds:.1f};"
         f"cache_hits={warming.cache_hits}")
    emit("index/warm_core_vs_cold_pct_p1", warm_pct + 1.0,
         f"warm_core_s={warm.core_seconds:.1f};"
         f"cache_hits={warm.cache_hits}")
    emit("index/warm_miss_rate_pct_p1",
         100.0 * (1.0 - warm.hit_rate) + 1.0,
         f"hit_rate={warm.hit_rate:.3f};jobs={len(warm.records)}")
    emit("index/sim_wall_us", wall_us,
         f"end_t={warm.end_time:.1f}s;jobs={NUM_JOBS}x3")
    _fused_rows()


def check() -> None:
    """CI warm-cache smoke assertions (ISSUE-5 acceptance)."""
    trace = _trace()
    cold_a, warming_a, warm_a = _drive_legs(trace)
    cold_b, warming_b, warm_b = _drive_legs(trace)
    assert (cold_a, warming_a, warm_a) == (cold_b, warming_b, warm_b), \
        "cache-aware serving sim is not replay-deterministic"
    disabled = _drive(trace, ResultCache(capacity=0))
    assert disabled == cold_a, (
        "capacity-0 cache diverged from the uncached PR-4 serving path — "
        "cache-aware admission must degenerate exactly when cold")
    assert warm_a.hit_rate == 1.0, \
        f"warm SLA hit-rate {warm_a.hit_rate:.3f} < 1.0"
    assert cold_a.hit_rate == 1.0, \
        f"cold SLA hit-rate {cold_a.hit_rate:.3f} < 1.0 (trace too tight)"
    reduction = 1.0 - warm_a.core_seconds / cold_a.core_seconds
    assert reduction >= 0.30, (
        f"warm-cache core-hours reduction {100 * reduction:.1f}% < 30% "
        f"(cold {cold_a.core_seconds:.1f} vs warm {warm_a.core_seconds:.1f})")
    print(f"index_cache --check OK: cold_core_s={cold_a.core_seconds:.1f} "
          f"warming={warming_a.core_seconds:.1f} "
          f"warm={warm_a.core_seconds:.1f} "
          f"(reduction {100 * reduction:.1f}%), warm hit_rate="
          f"{warm_a.hit_rate:.3f}, cold == uncached bit-for-bit")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="assert the CI smoke criteria instead of emitting "
                         "benchmark rows")
    if ap.parse_args().check:
        check()
    else:
        run()
