"""Paper Eq. 2: Cochran sample-size worked example + grid.

Asserts the paper's number exactly (99% CI, p=.5, e=5% -> 664) and sweeps
the (confidence, error) grid the paper names as the common choices.
"""

from __future__ import annotations

from repro.core import cochran_sample_size

from .common import emit, timed


def run() -> None:
    plan, us = timed(cochran_sample_size, 0.99, 0.50, 0.05)
    assert plan.size == 664, f"Eq.2 mismatch: {plan.size} != 664"
    emit("eq2/paper_example", us, f"s={plan.size};raw={plan.raw:.2f}")
    for ci in (0.90, 0.95, 0.99):
        for e in (0.01, 0.03, 0.05):
            p = cochran_sample_size(ci, 0.50, e)
            emit(f"eq2/ci{int(ci * 100)}_e{int(e * 100)}", 0.0,
                 f"s={p.size}")
    # finite-population correction (beyond-paper robustness)
    p = cochran_sample_size(0.99, 0.50, 0.05, population=1000)
    emit("eq2/fpc_X1000", 0.0, f"s={p.size}")
