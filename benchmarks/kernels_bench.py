"""Kernel micro-benchmarks: Pallas (interpret) vs jnp-oracle parity + timing.

Wall times on CPU measure the oracle path (the deployment path off-TPU);
the Pallas interpret runs validate numerics at benchmark shapes. On TPU the
same harness times the real kernels (force="pallas", interpret off).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.ell_spmv import ell_spmm_pallas, ell_spmv_pallas
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.flash_attention import flash_attention_pallas

from .common import emit, timed


def run() -> None:
    key = jax.random.PRNGKey(0)
    # flash attention at a serving-ish shape
    B, Sq, Skv, Hq, Hkv, Dh = 2, 256, 256, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, Dh))
    k = jax.random.normal(ks[1], (B, Skv, Hkv, Dh))
    v = jax.random.normal(ks[2], (B, Skv, Hkv, Dh))
    refo, us = timed(lambda: np.asarray(ref.flash_attention_ref(q, k, v)))
    pal = flash_attention_pallas(q, k, v)
    err = float(jnp.abs(pal - refo).max())
    emit("kernels/flash_attention", us, f"maxerr={err:.2e};shape=B{B}S{Sq}H{Hq}")

    # ell spmv at a push-sweep shape
    n, K = 4096, 32
    nbr = jax.random.randint(ks[0], (n, K), 0, n)
    msk = jax.random.bernoulli(ks[1], 0.8, (n, K))
    w = jax.random.normal(ks[2], (n, K))
    x = jax.random.normal(key, (n,))
    refo, us = timed(lambda: np.asarray(ref.ell_spmv_ref(nbr, msk, x, w)))
    pal = ell_spmv_pallas(nbr, msk, w, x)
    err = float(jnp.abs(pal - refo).max())
    emit("kernels/ell_spmv", us, f"maxerr={err:.2e};n={n};K={K}")

    # batched ell spmm at the fused push shape (query batch on the lane axis)
    Bq = 8
    xb = jax.random.normal(key, (Bq, n))
    refo, us = timed(lambda: np.asarray(ref.ell_spmm_ref(nbr, msk, xb, w)))
    pal = ell_spmm_pallas(nbr, msk, w, xb)
    err = float(jnp.abs(pal - refo).max())
    emit("kernels/ell_spmm", us, f"maxerr={err:.2e};n={n};K={K};B={Bq}")

    # fused push-threshold variant (the forward_push inner loop)
    thr = jnp.abs(jax.random.normal(ks[1], (n,))) * 0.1
    refo, us = timed(lambda: np.asarray(
        ref.ell_spmm_ref(nbr, msk, xb, w, threshold=thr)))
    pal = ell_spmm_pallas(nbr, msk, w, xb, thr)
    err = float(jnp.abs(pal - refo).max())
    emit("kernels/ell_spmm_fused_push", us,
         f"maxerr={err:.2e};n={n};K={K};B={Bq}")

    # embedding bag at a DIN-ish shape
    V, d, Bb, L = 50_000, 18, 512, 100
    table = jax.random.normal(ks[0], (V, d))
    ids = jax.random.randint(ks[1], (Bb, L), 0, V)
    wts = jax.random.uniform(ks[2], (Bb, L))
    refo, us = timed(lambda: np.asarray(ref.embedding_bag_ref(table, ids, wts)))
    pal = embedding_bag_pallas(table, ids, wts)
    err = float(jnp.abs(pal - refo).max())
    emit("kernels/embedding_bag", us, f"maxerr={err:.2e};V={V};B={Bb};L={L}")
