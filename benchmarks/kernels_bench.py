"""Kernel micro-benchmarks: Pallas (interpret) vs jnp-oracle parity + timing.

Wall times on CPU measure the oracle path (the deployment path off-TPU);
the Pallas interpret runs validate numerics at benchmark shapes. On TPU the
same harness times the real kernels (force="pallas", interpret off).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels.ell_spmv import (_spmm_virtual_rows, ell_spmm_pallas,
                                    ell_spmm_sliced_pallas, ell_spmv_pallas)
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.walk_gather import walk_endpoint_gather_pallas
from repro.ppr.graph import Graph

from .common import emit, timed, timed_aot


def run() -> None:
    key = jax.random.PRNGKey(0)
    # flash attention at a serving-ish shape
    B, Sq, Skv, Hq, Hkv, Dh = 2, 256, 256, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, Dh))
    k = jax.random.normal(ks[1], (B, Skv, Hkv, Dh))
    v = jax.random.normal(ks[2], (B, Skv, Hkv, Dh))
    refo, us = timed(lambda: np.asarray(ref.flash_attention_ref(q, k, v)))
    pal = flash_attention_pallas(q, k, v)
    err = float(jnp.abs(pal - refo).max())
    emit("kernels/flash_attention", us, f"maxerr={err:.2e};shape=B{B}S{Sq}H{Hq}")

    # ell spmv at a push-sweep shape
    n, K = 4096, 32
    nbr = jax.random.randint(ks[0], (n, K), 0, n)
    msk = jax.random.bernoulli(ks[1], 0.8, (n, K))
    w = jax.random.normal(ks[2], (n, K))
    x = jax.random.normal(key, (n,))
    refo, us = timed(lambda: np.asarray(ref.ell_spmv_ref(nbr, msk, x, w)))
    pal = ell_spmv_pallas(nbr, msk, w, x)
    err = float(jnp.abs(pal - refo).max())
    emit("kernels/ell_spmv", us, f"maxerr={err:.2e};n={n};K={K}")

    # batched ell spmm at the fused push shape (query batch on the lane axis)
    Bq = 8
    xb = jax.random.normal(key, (Bq, n))
    refo, us = timed(lambda: np.asarray(ref.ell_spmm_ref(nbr, msk, xb, w)))
    pal = ell_spmm_pallas(nbr, msk, w, xb)
    err = float(jnp.abs(pal - refo).max())
    emit("kernels/ell_spmm", us, f"maxerr={err:.2e};n={n};K={K};B={Bq}")
    # device-time row (jax.profiler-backed AOT harness, DESIGN.md §15):
    # steady-state us on the compiled dispatch, compile cost split out
    spmm_fn = jax.jit(lambda a, b, c, d: ops.ell_spmm(a, b, c, d))
    _, dev_us, comp_us = timed_aot(spmm_fn, nbr, msk, w, xb)
    emit("kernels/ell_spmm_dev", dev_us,
         f"compile_us={comp_us:.0f};n={n};K={K};B={Bq}")

    # fused push-threshold variant (the forward_push inner loop)
    thr = jnp.abs(jax.random.normal(ks[1], (n,))) * 0.1
    refo, us = timed(lambda: np.asarray(
        ref.ell_spmm_ref(nbr, msk, xb, w, threshold=thr)))
    pal = ell_spmm_pallas(nbr, msk, w, xb, thr)
    err = float(jnp.abs(pal - refo).max())
    emit("kernels/ell_spmm_fused_push", us,
         f"maxerr={err:.2e};n={n};K={K};B={Bq}")

    # sliced ELL at a power-law shape (hub in-degree ~ n): the web-scale
    # serving layout (DESIGN.md §8). Also reports the resident ELL bytes —
    # dense (n, k_max) vs sliced (n_virtual, W) — so layout regressions
    # (e.g. a worse width heuristic) fail the tolerance gate on peak MiB.
    rng = np.random.default_rng(0)
    n_pl = 4096
    src = np.concatenate([np.arange(1, n_pl),
                          rng.integers(0, n_pl, 4 * n_pl)])
    dst = np.concatenate([np.zeros(n_pl - 1, np.int64),
                          rng.integers(0, n_pl, 4 * n_pl)])
    g = Graph.from_edges(n_pl, src, dst, name="powerlaw-bench")
    sl = g.ell_in_sliced()
    xp = jax.random.normal(key, (Bq, n_pl))
    s_nbr, s_msk = jnp.asarray(sl.neighbors), jnp.asarray(sl.mask)
    s_w, s_map = jnp.asarray(sl.weights), jnp.asarray(sl.row_map)
    refo, us = timed(lambda: np.asarray(ref.ell_spmm_sliced_ref(
        s_nbr, s_msk, xp, s_w, row_map=s_map)))
    pal = ell_spmm_sliced_pallas(s_nbr, s_msk, s_w, s_map, xp)
    err = float(jnp.abs(pal - refo).max())
    emit("kernels/ell_spmm_sliced", us,
         f"maxerr={err:.2e};n={n_pl};W={sl.width};nv={sl.n_virtual};B={Bq}")
    sliced_oracle_us = us

    # in-kernel fused fold (DESIGN.md §15): the sliced kernel now folds its
    # virtual-row partials into true rows inside the Pallas grid instead of a
    # host-side segment_sum pass. Parity bar is bit-exactness against the
    # former two-pass path (identical partials, identical ascending fold
    # order), plus speedup vs the eager oracle row above. Timing is AOT
    # device time on the jitted dispatch — compile cost is its own field.
    yT_part = _spmm_virtual_rows(s_nbr, s_msk, s_w, xp, None,
                                 block_n=256, interpret=True)
    old_fold = jax.ops.segment_sum(
        yT_part[:sl.n_virtual], s_map, num_segments=n_pl,
        indices_are_sorted=True).T
    bit_exact = bool(np.array_equal(np.asarray(pal), np.asarray(old_fold)))
    fold_fn = jax.jit(lambda a, b, c, d, e: ops.ell_spmm_sliced(a, b, c, d, e))
    _, dev_us, comp_us = timed_aot(fold_fn, s_nbr, s_msk, s_w, s_map, xp)
    emit("kernels/ell_spmm_sliced_fused_fold", dev_us,
         f"bit_exact_vs_host_fold={int(bit_exact)};"
         f"speedup_vs_host_fold={sliced_oracle_us / max(dev_us, 1e-9):.2f}x;"
         f"compile_us={comp_us:.0f};n={n_pl};W={sl.width};B={Bq}")
    dense_mib = g.ell_in_dense_nbytes() / 2**20
    sliced_mib = sl.nbytes / 2**20
    emit("kernels/ell_peak_mib", sliced_mib * 1e3,   # milli-MiB for precision
         f"sliced_MiB={sliced_mib:.2f};dense_MiB={dense_mib:.2f};"
         f"ratio={dense_mib / sliced_mib:.0f}x;n={n_pl};W={sl.width}")

    # embedding bag at a DIN-ish shape
    V, d, Bb, L = 50_000, 18, 512, 100
    table = jax.random.normal(ks[0], (V, d))
    ids = jax.random.randint(ks[1], (Bb, L), 0, V)
    wts = jax.random.uniform(ks[2], (Bb, L))
    refo, us = timed(lambda: np.asarray(ref.embedding_bag_ref(table, ids, wts)))
    pal = embedding_bag_pallas(table, ids, wts)
    err = float(jnp.abs(pal - refo).max())
    emit("kernels/embedding_bag", us, f"maxerr={err:.2e};V={V};B={Bb};L={L}")

    # walk-endpoint gather at the index-backed fused walk shape
    # (DESIGN.md §11): n nodes x W stored lanes, one query block of Bq rows
    n_wi, W_wi = 4096, 256
    endpoints = jax.random.randint(ks[0], (n_wi, W_wi), 0, n_wi)
    budget = jax.random.randint(ks[1], (n_wi,), 0, W_wi + 1)
    starts = jax.random.randint(ks[2], (Bq, W_wi), 0, n_wi)
    w_lanes = jax.random.uniform(key, (Bq, W_wi))
    refo, us = timed(lambda: np.asarray(ref.walk_endpoint_gather_ref(
        endpoints, budget, starts, w_lanes)))
    pal = walk_endpoint_gather_pallas(endpoints, budget, starts, w_lanes)
    err = float(jnp.abs(pal - refo).max())
    emit("kernels/walk_endpoint_gather", us,
         f"maxerr={err:.2e};n={n_wi};W={W_wi};B={Bq}")
    gather_fn = jax.jit(
        lambda a, b, c, d: ops.walk_endpoint_gather(a, b, c, d))
    _, dev_us, comp_us = timed_aot(gather_fn, endpoints, budget, starts,
                                   w_lanes)
    emit("kernels/walk_endpoint_gather_dev", dev_us,
         f"compile_us={comp_us:.0f};n={n_wi};W={W_wi};B={Bq}")
