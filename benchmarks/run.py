"""Benchmark entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only eq2,fig2] [--full]
                                            [--json out.json]

Output: ``name,us_per_call,derived`` CSV (one row per measurement).
``--json`` additionally persists every emitted row as JSON so the per-PR
``BENCH_*.json`` perf trajectory can be recorded by CI (tools/ci.sh).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

from . import common


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: eq2,table1,fig2,fig3,kernels,roofline,"
                         "fora_hot,serving,index")
    ap.add_argument("--full", action="store_true",
                    help="wider Fig.2 grid (slower)")
    ap.add_argument("--json", default="", metavar="OUT",
                    help="also write all measurements to OUT as JSON")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    from . import (eq2_sample_size, fig2_cores, fig3_scaling, fora_hot_path,
                   index_cache, kernels_bench, roofline, serving_sim,
                   table1_datasets)

    suites = [
        ("eq2", eq2_sample_size.run, {}),
        ("table1", table1_datasets.run, {}),
        ("kernels", kernels_bench.run, {}),
        ("fora_hot", fora_hot_path.run, {}),
        ("serving", serving_sim.run, {}),
        ("index", index_cache.run, {}),
        ("fig2", fig2_cores.run,
         {"grid": fig2_cores.FULL_GRID if args.full else
          fig2_cores.DEFAULT_GRID}),
        ("fig3", fig3_scaling.run, {}),
        ("roofline", roofline.run, {}),
    ]
    common.reset_records()
    known = {name for name, _, _ in suites}
    if want and not want <= known:
        ap.error(f"unknown suite(s) {sorted(want - known)}; "
                 f"choose from {sorted(known)}")
    print("name,us_per_call,derived")
    failures = 0
    for name, fn, kw in suites:
        if want and name not in want:
            continue
        t0 = time.perf_counter()
        try:
            fn(**kw)
            common.emit(f"suite/{name}",
                        (time.perf_counter() - t0) * 1e6, "ok")
        except Exception as e:      # noqa: BLE001
            failures += 1
            common.emit(f"suite/{name}", 0,
                        f"FAILED:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if args.json:
        payload = {"rows": common.RECORDS, "failures": failures}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
