"""Benchmark entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only eq2,fig2] [--full]

Output: ``name,us_per_call,derived`` CSV (one row per measurement).
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: eq2,table1,fig2,fig3,kernels,roofline")
    ap.add_argument("--full", action="store_true",
                    help="wider Fig.2 grid (slower)")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    from . import (eq2_sample_size, fig2_cores, fig3_scaling, kernels_bench,
                   roofline, table1_datasets)

    suites = [
        ("eq2", eq2_sample_size.run, {}),
        ("table1", table1_datasets.run, {}),
        ("kernels", kernels_bench.run, {}),
        ("fig2", fig2_cores.run,
         {"grid": fig2_cores.FULL_GRID if args.full else
          fig2_cores.DEFAULT_GRID}),
        ("fig3", fig3_scaling.run, {}),
        ("roofline", roofline.run, {}),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn, kw in suites:
        if want and name not in want:
            continue
        t0 = time.perf_counter()
        try:
            fn(**kw)
            print(f"suite/{name},{(time.perf_counter() - t0) * 1e6:.0f},ok")
        except Exception as e:      # noqa: BLE001
            failures += 1
            print(f"suite/{name},0,FAILED:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
