"""Shared benchmark helpers. Output convention: ``name,us_per_call,derived``
CSV rows (derived carries the benchmark-specific payload). Every emitted row
is also appended to ``RECORDS`` so ``run.py --json`` can persist the full
measurement set (the per-PR BENCH_*.json perf trajectory)."""

from __future__ import annotations

import time
from collections.abc import Callable

RECORDS: list[dict] = []


def timed(fn: Callable, *args, repeats: int = 5, **kwargs):
    """(result, us_per_call) with a warmup call.

    Reports the MIN over ``repeats`` — the steady-state floor. The mean folds
    scheduler preemptions into the number; on a loaded box that noise swings
    2-4x and would flap the CI tolerance gate (tools/bench_compare.py), while
    the per-call floor is reproducible."""
    fn(*args, **kwargs)
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    RECORDS.append({"name": name, "us_per_call": round(us_per_call, 1),
                    "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}")


def reset_records() -> None:
    RECORDS.clear()
