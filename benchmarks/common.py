"""Shared benchmark helpers. Output convention: ``name,us_per_call,derived``
CSV rows (derived carries the benchmark-specific payload). Every emitted row
is also appended to ``RECORDS`` so ``run.py --json`` can persist the full
measurement set (the per-PR BENCH_*.json perf trajectory)."""

from __future__ import annotations

import time
from collections.abc import Callable

RECORDS: list[dict] = []


def timed(fn: Callable, *args, repeats: int = 5, **kwargs):
    """(result, us_per_call) with compilation hoisted out of the timed region.

    Reports the MIN over ``repeats`` — the steady-state floor. The mean folds
    scheduler preemptions into the number; on a loaded box that noise swings
    2-4x and would flap the CI tolerance gate (tools/bench_compare.py), while
    the per-call floor is reproducible.

    The warmup call absorbs tracing + XLA compilation; its wall time is kept
    on ``timed.last_compile_us`` so callers can report compile cost as a
    separate derived field instead of conflating it with steady state (the
    pre-PR-9 bug: a jitted fn whose STATICS differ between the warmup and the
    timed calls re-jits inside the timed region — keep statics fixed across
    all calls, or use :func:`timed_aot` which pins one AOT executable)."""
    t0 = time.perf_counter()
    fn(*args, **kwargs)
    timed.last_compile_us = (time.perf_counter() - t0) * 1e6
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6


timed.last_compile_us = 0.0


def timed_aot(fn: Callable, *args, repeats: int = 5):
    """(result, device_us, compile_us) via one AOT-compiled executable.

    Delegates to ``repro.kernels.autotune.measure_compiled``: lower/compile
    once outside the timed region, stage inputs with device_put, time
    steady-state calls under ``jax.profiler`` step annotations. ``fn`` must
    take its arrays positionally (no array closures — they would be baked in
    as compile-time constants)."""
    from repro.kernels.autotune import measure_compiled

    return measure_compiled(fn, *args, repeats=repeats)


def emit(name: str, us_per_call: float, derived: str) -> None:
    RECORDS.append({"name": name, "us_per_call": round(us_per_call, 1),
                    "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}")


def reset_records() -> None:
    RECORDS.clear()
